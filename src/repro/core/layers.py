"""Functional BCPNN layers (the DSL's building blocks).

Each layer is a pure-functional object: `init(key) -> LayerState` plus
`forward(state, x)` / `train_batch(state, x, [y])` transition functions that
jit/scan/shard_map cleanly.  The Keras-like imperative API in
``repro.core.network`` is a thin veneer over these.

Two layer types, matching the paper's Listing 1:

* :class:`StructuralPlasticityLayer` — input -> hidden, unsupervised Hebbian
  learning with a dynamic receptive-field mask (Alg. 1).
* :class:`DenseLayer` — hidden -> output, supervised readout: identical
  marginal learning but with the post-activations clamped to one-hot labels.

`use_kernels=True` routes the hot ops through the Pallas TPU kernels
(interpret-mode on CPU); False uses the pure-jnp reference path. Both paths
are numerically validated against each other in tests.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import learning, plasticity
from repro.core.learning import MarginalState
from repro.core.plasticity import PlasticityState
from repro.core.units import UnitLayout


class LayerState(NamedTuple):
    """Learnable state of a BCPNN layer (a pytree).

    w/b are *derived* from marginals each cycle but cached here because
    inference uses them without touching marginals.
    """

    marginals: MarginalState
    w: jnp.ndarray
    b: jnp.ndarray
    plast: Optional[PlasticityState]
    step: jnp.ndarray  # int32 scalar, counts train batches seen


@dataclasses.dataclass(frozen=True)
class BCPNNLayerSpec:
    """Hyperparameters shared by both layer types.

    precision: optional repro.precision.PrecisionPolicy — routes the whole
    datapath through the reduced-mantissa emulation (the paper's FPGA
    BF14..BF28 study).  Mutually composable with use_kernels (bf_round is
    itself a Pallas kernel).
    """

    pre: UnitLayout
    post: UnitLayout
    lam: float = 0.001
    k_b: float = 1.0
    n_cycles: int = 1
    use_kernels: bool = False
    dtype: jnp.dtype = jnp.float32
    precision: object = None
    gain: float = 1.0  # softmax inverse temperature (soft-WTA sharpness)
    # One-dispatch training: forward + softmax + EWMA + weights in a single
    # Pallas mega-kernel (repro.kernels.bcpnn_phase).  Requires use_kernels;
    # composes with the quantized *state* tier but not with a reduced
    # *datapath* (the per-stage rounding of the bf emulation cannot run
    # inside the fused kernel).
    fused_phase: bool = False

    def __post_init__(self):
        if self.fused_phase:
            if not self.use_kernels:
                raise ValueError("fused_phase=True requires use_kernels=True")
            if _datapath_policy(self) is not None:
                raise ValueError(
                    "fused_phase is incompatible with a reduced-precision "
                    "datapath (precision fmt "
                    f"{self.precision.fmt.name!r}); only the quantized state "
                    "tier (state_format=) composes with the fused kernel"
                )

    @property
    def n_pre(self) -> int:
        return self.pre.n_units

    @property
    def n_post(self) -> int:
        return self.post.n_units


def _datapath_policy(spec: "BCPNNLayerSpec"):
    """The PrecisionPolicy if it actually reduces the *datapath* (non-identity
    fmt) — a policy carrying only a ``state_format`` is not a datapath."""
    p = spec.precision
    if p is None or p.fmt.is_identity:
        return None
    return p


def _state_format(spec: "BCPNNLayerSpec"):
    """The storage format of the quantized state tier, if any."""
    p = spec.precision
    if p is not None and getattr(p, "has_state_tier", False):
        return p.state_format
    return None


def _forward(spec: BCPNNLayerSpec, state: LayerState, x: jnp.ndarray) -> jnp.ndarray:
    """s = x @ (w o mask) + b; softmax per HCU. Kernel or reference path."""
    mask = (
        state.plast.unit_mask(spec.pre, spec.post)
        if state.plast is not None
        else None
    )
    if _datapath_policy(spec) is not None:
        from repro.precision.policy import quantized_forward

        return quantized_forward(
            x, state.w, state.b, spec.post, spec.precision, mask, gain=spec.gain
        )
    if spec.use_kernels:
        from repro.kernels import ops as kops

        s = kops.masked_matmul(x, state.w, state.b, mask=mask)
        if spec.gain != 1.0:
            s = s * spec.gain
        return kops.hcu_softmax(s, n_hcu=spec.post.n_hcu, n_mcu=spec.post.n_mcu)
    return learning.forward(x, state.w, state.b, spec.post, mask=mask, gain=spec.gain)


def _learn(
    spec: BCPNNLayerSpec, state: LayerState, ai: jnp.ndarray, aj: jnp.ndarray
) -> LayerState:
    """n_cycles of the EWMA marginal -> weight update (Alg.1 L10-16)."""
    mask = (
        state.plast.unit_mask(spec.pre, spec.post)
        if state.plast is not None
        else None
    )

    marg, w, b = state.marginals, state.w, state.b
    sfmt = _state_format(spec)
    for _ in range(spec.n_cycles):
        if _datapath_policy(spec) is not None:
            from repro.precision.policy import quantized_learning_cycle

            marg, w, b = quantized_learning_cycle(
                marg, ai, aj, spec.lam, spec.precision, spec.k_b, mask=mask
            )
        elif spec.use_kernels:
            from repro.kernels import ops as kops

            marg, w, b = kops.bcpnn_update(
                marg, ai, aj, lam=spec.lam, k_b=spec.k_b, mask=mask,
                state_format=sfmt, layout=spec.post,
            )
        else:
            if sfmt is not None:
                # Traces may be stored bf16; upcast so the EWMA runs in f32
                # (bf16 * python-float would weak-promote to bf16 arithmetic).
                marg = MarginalState(
                    ci=marg.ci.astype(jnp.float32),
                    cj=marg.cj.astype(jnp.float32),
                    cij=marg.cij.astype(jnp.float32),
                )
            marg, w, b = learning.learning_cycle(
                marg, ai, aj, spec.lam, spec.k_b, mask=mask
            )
            if sfmt is not None:
                from repro.precision.policy import state_quantized_cycle

                marg, w, b = state_quantized_cycle(
                    marg, spec.precision, k_b=spec.k_b, mask=mask
                )
    return LayerState(
        marginals=marg, w=w, b=b, plast=state.plast, step=state.step + 1
    )


def _fused_train_batch(
    spec: BCPNNLayerSpec, state: LayerState, x: jnp.ndarray
) -> Tuple[LayerState, jnp.ndarray]:
    """The one-dispatch training path: the whole Alg.1 batch iteration
    (forward + HCU softmax + EWMA marginals + weight/bias epilogue) in a
    single `bcpnn_phase` Pallas call, bit-exact with the unfused kernel
    composition."""
    from repro.kernels import ops as kops

    mask = (
        state.plast.unit_mask(spec.pre, spec.post)
        if state.plast is not None
        else None
    )
    marg, w, b, aj = kops.bcpnn_phase(
        state.marginals, x, state.w, state.b, spec.post,
        lam=spec.lam, k_b=spec.k_b, gain=spec.gain, mask=mask,
        n_cycles=spec.n_cycles, state_format=_state_format(spec),
    )
    new_state = LayerState(
        marginals=marg, w=w, b=b, plast=state.plast, step=state.step + 1
    )
    return new_state, aj


class StructuralPlasticityLayer:
    """Unsupervised BCPNN layer with dynamic receptive fields (Alg. 1)."""

    def __init__(
        self,
        pre: UnitLayout,
        post: UnitLayout,
        fan_in: Optional[int] = None,
        lam: float = 0.001,
        k_b: float = 1.0,
        n_cycles: int = 1,
        mask_update_every: Optional[int] = None,
        use_kernels: bool = False,
        precision=None,
        init_jitter: float = 1.0,
        gain: float = 1.0,
        fused_phase: bool = False,
    ):
        self.spec = BCPNNLayerSpec(
            pre=pre, post=post, lam=lam, k_b=k_b, n_cycles=n_cycles,
            use_kernels=use_kernels, precision=precision, gain=gain,
            fused_phase=fused_phase,
        )
        self.init_jitter = init_jitter
        self.fan_in = fan_in if fan_in is not None else pre.n_hcu
        # Alg.1 L4: "if i_B % N_HCU == 0: update plasticity mask"
        self.mask_update_every = (
            mask_update_every if mask_update_every is not None else post.n_hcu
        )

    def init(self, key: jax.Array) -> LayerState:
        k_marg, key = jax.random.split(key)
        marg = learning.init_marginals(
            self.spec.n_pre, self.spec.n_post, self.spec.pre, self.spec.post,
            dtype=self.spec.dtype, key=k_marg, jitter=self.init_jitter,
        )
        if self.fan_in < self.spec.pre.n_hcu:
            plast = plasticity.init_random_mask(
                key, self.spec.pre, self.spec.post, self.fan_in
            )
        else:
            plast = plasticity.full_mask(self.spec.pre, self.spec.post)
        w, b = learning.weights_from_marginals(marg, self.spec.k_b)
        w = w * plast.unit_mask(self.spec.pre, self.spec.post)
        return LayerState(
            marginals=marg, w=w, b=b, plast=plast, step=jnp.zeros((), jnp.int32)
        )

    def forward(self, state: LayerState, x: jnp.ndarray) -> jnp.ndarray:
        return _forward(self.spec, state, x)

    def train_batch(self, state: LayerState, x: jnp.ndarray) -> Tuple[LayerState, jnp.ndarray]:
        """One Alg.1 batch iteration: (maybe) rewire, forward, learn."""
        state = self.maybe_update_mask(state)
        if self.spec.fused_phase:
            return _fused_train_batch(self.spec, state, x)
        aj = _forward(self.spec, state, x)
        new_state = _learn(self.spec, state, x, aj)
        return new_state, aj

    def maybe_update_mask(self, state: LayerState) -> LayerState:
        """Rewire every `mask_update_every` batches (Alg.1 L4-6), under lax.cond
        so the whole train step remains a single jitted program."""
        if self.fan_in >= self.spec.pre.n_hcu:
            return state  # dense: nothing to rewire

        def rewire(s: LayerState) -> LayerState:
            new_plast = plasticity.update_mask(
                s.plast, s.marginals, self.spec.pre, self.spec.post
            )
            # Re-apply the (possibly changed) mask to the cached weights.
            w = s.w * new_plast.unit_mask(self.spec.pre, self.spec.post)
            return LayerState(s.marginals, w, s.b, new_plast, s.step)

        do = (state.step % self.mask_update_every) == 0
        return jax.lax.cond(do, rewire, lambda s: s, state)


class DenseLayer:
    """Supervised BCPNN readout layer: marginal learning against one-hot
    targets (the paper's output layer; "training of the output layer is
    similar" to Alg. 1, with a_k := onehot(y))."""

    def __init__(
        self,
        pre: UnitLayout,
        post: UnitLayout,
        lam: float = 0.001,
        k_b: float = 1.0,
        n_cycles: int = 1,
        use_kernels: bool = False,
        precision=None,
        gain: float = 1.0,
    ):
        self.spec = BCPNNLayerSpec(
            pre=pre, post=post, lam=lam, k_b=k_b, n_cycles=n_cycles,
            use_kernels=use_kernels, precision=precision, gain=gain,
        )

    def init(self, key: jax.Array) -> LayerState:
        del key
        marg = learning.init_marginals(
            self.spec.n_pre, self.spec.n_post, self.spec.pre, self.spec.post,
            dtype=self.spec.dtype,
        )
        w, b = learning.weights_from_marginals(marg, self.spec.k_b)
        return LayerState(
            marginals=marg, w=w, b=b, plast=None, step=jnp.zeros((), jnp.int32)
        )

    def forward(self, state: LayerState, x: jnp.ndarray) -> jnp.ndarray:
        return _forward(self.spec, state, x)

    def train_batch(
        self, state: LayerState, x: jnp.ndarray, y: jnp.ndarray
    ) -> Tuple[LayerState, jnp.ndarray]:
        """Supervised batch: targets (int labels or already-one-hot) become
        the post-activations for the marginal update."""
        if y.ndim == x.ndim - 1:  # integer labels -> one-hot over output units
            aj = jax.nn.one_hot(y, self.spec.n_post, dtype=x.dtype)
        else:
            aj = y
        new_state = _learn(self.spec, state, x, aj)
        return new_state, aj
