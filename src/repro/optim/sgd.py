"""SGD with (Nesterov) momentum — the baseline optimizer the paper's PyTorch
comparison uses, and the cheap option for the supervised BCPNN readout."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


@dataclasses.dataclass(frozen=True)
class SGD:
    learning_rate: Union[float, Schedule] = 1e-2
    momentum: float = 0.9
    nesterov: bool = False
    weight_decay: float = 0.0

    def init(self, params) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
        )

    def update(self, grads, state: SGDState, params):
        step = state.step + 1
        lr = self.learning_rate(step) if callable(self.learning_rate) else self.learning_rate

        def upd(g, m, p):
            g32 = g.astype(jnp.float32)
            if self.weight_decay:
                g32 = g32 + self.weight_decay * p.astype(jnp.float32)
            m = self.momentum * m + g32
            d = g32 + self.momentum * m if self.nesterov else m
            return (-lr * d).astype(p.dtype), m

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.momentum)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            treedef.unflatten([o[0] for o in out]),
            SGDState(step=step, momentum=treedef.unflatten([o[1] for o in out])),
        )
