"""Gradient accumulation (microbatching) — the standard memory/roofline lever
for the train_4k cells: loss over global_batch=256 is accumulated over
`n_micro` sequential microbatches inside one jitted step via lax.scan, so the
activation working set scales with the microbatch, not the global batch."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def microbatched_value_and_grad(
    loss_fn: Callable,
    n_micro: int,
) -> Callable:
    """Wrap loss_fn(params, batch) -> scalar into an accumulated grad fn.

    batch: pytree whose leaves have a leading global-batch axis divisible by
    n_micro.  Returns fn(params, batch) -> (mean_loss, mean_grads).  Uses
    lax.scan so the HLO stays O(1) in n_micro (compile-time critical for the
    dry-run).
    """
    if n_micro <= 1:
        vg = jax.value_and_grad(loss_fn)
        return lambda p, b: vg(p, b)

    def split(b):
        return jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]), b
        )

    def fn(params, batch):
        micro = split(batch)
        vg = jax.value_and_grad(loss_fn)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = vg(params, mb)
            grad_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
            )
            return (loss_acc + loss, grad_acc), None

        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss_sum, grad_sum), _ = jax.lax.scan(body, (0.0, zero), micro)
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree_util.tree_map(lambda g: g * inv, grad_sum)

    return fn
