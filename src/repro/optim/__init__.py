# Optimizer substrate: from-scratch AdamW/SGD (no optax in the container),
# LR schedules, microbatched grad accumulation, and the distributed-
# optimization tricks (top-k + error-feedback compression, int8 all-reduce).
from repro.optim import adamw, sgd, schedules, compression, accumulation
from repro.optim.adamw import AdamW, AdamWState, apply_updates
from repro.optim.sgd import SGD, SGDState
from repro.optim.schedules import constant, warmup_cosine, warmup_linear
from repro.optim.accumulation import microbatched_value_and_grad

__all__ = [
    "adamw", "sgd", "schedules", "compression", "accumulation",
    "AdamW", "AdamWState", "apply_updates", "SGD", "SGDState",
    "constant", "warmup_cosine", "warmup_linear",
    "microbatched_value_and_grad",
]
