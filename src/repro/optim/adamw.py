"""AdamW, implemented from scratch (no optax dependency).

API shape follows the optax convention (init/update returning *updates* to be
added to params) so the trainer code stays composable with schedules,
gradient accumulation and compression wrappers in this package.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any  # first moment pytree
    nu: Any  # second moment pytree


def _to_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    """Decoupled weight decay Adam (Loshchilov & Hutter).

    Moments are stored in f32 regardless of param dtype (mixed-precision
    training keeps bf16 params with f32 optimizer state — justified for
    BCPNN-adjacent workloads by the paper's own BF16-resilience result).
    """

    learning_rate: Union[float, Schedule] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    mask: Optional[Callable[[Any], Any]] = None  # pytree of bools for decay

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        lr = _to_schedule(self.learning_rate)(step)
        # Bias-corrected moments.
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / c1
            vhat = v / c2
            u = -lr * (mhat / (jnp.sqrt(vhat) + self.eps))
            if self.weight_decay:
                decay = self.weight_decay
                u = u - lr * decay * p.astype(jnp.float32)
            return u.astype(p.dtype), m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
