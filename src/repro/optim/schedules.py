"""Learning-rate schedules (warmup + cosine/linear decay, constant)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    """Linear warmup to `peak` then cosine decay to `floor` — the standard
    LM-pretraining schedule used by every assigned architecture."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        t = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        t = jnp.clip(t, 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def warmup_linear(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        t = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        t = jnp.clip(t, 0.0, 1.0)
        lin = peak + (floor - peak) * t
        return jnp.where(step < warmup_steps, warm, lin)

    return sched
