"""Gradient compression for data-parallel all-reduce at 1000+ node scale.

Two schemes, both with the memory/bandwidth math that motivates them at pod
scale (ICI ~50 GB/s/link vs HBM 819 GB/s — DP all-reduce of full f32 grads
is the classic scaling wall):

* **Top-k sparsification with error feedback** (Lin et al., Deep Gradient
  Compression): keep the k largest-|g| entries per tensor, accumulate the
  residual locally and add it back next step.  Volume drops by ~dim/k.
  All-reduce of sparse (idx, val) pairs is emulated by scatter -> dense
  psum -> (values already dense) because TPU collectives are dense; the
  *wire volume model* is still recorded so the roofline collective term can
  be compared.  On real hardware one would all-gather (idx, val) pairs.

* **Int8 quantized all-reduce**: per-tensor symmetric scale, round-to-nearest
  stochastic-free; psum in int32 then dequantize.  4x volume reduction with
  unbiased-enough error for EWMA/Adam-smoothed training.

Both are pure functions usable inside shard_map (they call jax.lax collectives
when `axis` is given) or standalone (axis=None -> local, for tests).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads


def init_error_feedback(grads_like) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def _topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest-|x| entries (flattened)."""
    flat = jnp.abs(x.reshape(-1))
    k = min(k, flat.shape[0])
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress_allreduce(
    grads,
    ef: ErrorFeedbackState,
    k_fraction: float = 0.01,
    axes: Optional[Union[str, Sequence[str]]] = None,
) -> Tuple[Any, ErrorFeedbackState, float]:
    """Top-k + error feedback; returns (mean grads, new state, wire_fraction).

    wire_fraction is the modeled collective-volume ratio vs dense f32
    all-reduce ((idx int32 + val f32) * k vs dim * f32) for the roofline
    collective term.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        k = max(1, int(k_fraction * g32.size))
        mask = _topk_mask(g32, k)
        sent = g32 * mask
        new_r = g32 - sent
        if axes is not None:
            sent = jax.lax.pmean(sent, axes)
        return sent.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    wire_fraction = 2.0 * k_fraction  # (4B idx + 4B val) per kept vs 4B per dense
    return (
        treedef.unflatten([o[0] for o in out]),
        ErrorFeedbackState(residual=treedef.unflatten([o[1] for o in out])),
        wire_fraction,
    )


def int8_allreduce(
    grads, axes: Optional[Union[str, Sequence[str]]] = None
) -> Tuple[Any, float]:
    """Symmetric per-tensor int8 quantize -> psum(int32) -> dequantize.

    Returns (mean grads, wire_fraction=0.25).  The scale itself is maxed
    across shards first so quantization grids agree.
    """

    def one(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
        if axes is not None:
            scale = jax.lax.pmax(scale, axes)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        if axes is not None:
            tot = jax.lax.psum(q.astype(jnp.int32), axes)
            n = jax.lax.psum(jnp.ones((), jnp.int32), axes)
            return (tot.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(
                g.dtype
            )
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree_util.tree_map(one, grads), 0.25
