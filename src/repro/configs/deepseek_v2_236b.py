"""DeepSeek-V2 236B (MoE, MLA) — arXiv:2405.04434 + HF config (hf tier).

60L d_model=5120, 128 heads MLA (kv_lora=512, q_lora=1536, nope/rope head
dims 128/64, v_head 128), vocab 102400; MoE: 160 routed experts top-6 +
2 shared, expert FFN 1536, first layer dense (d_ff 12288).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=192,  # qk nope+rope dim (128+64); v_head_dim=128
    d_ff=12288,  # dense (first_dense_layers) FFN
    vocab_size=102400,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=48,
        q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
        v_head_dim=32, d_ff=128, vocab_size=256, n_experts=8, top_k=2,
        n_shared_experts=1, moe_d_ff=32, first_dense_layers=1, n_micro=1,
        q_chunk=32, kv_chunk=32, moe_impl="local", capacity_factor=8.0,
    )
