"""Mamba2-1.3b (SSD, attention-free) — arXiv:2405.21060 (unverified tier).

48L d_model=2048, ssm_state=128, expand=2 (d_inner 4096, 64 heads of 64),
vocab 50280.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=2048,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    norm="rmsnorm",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=3, d_model=64, ssm_state=16, ssm_head_dim=16,
        vocab_size=256, ssm_chunk=16, n_micro=1,
    )
