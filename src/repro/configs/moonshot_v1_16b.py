"""Moonlight-16B-A3B (kimi/moonshot MoE) — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048, 16 heads (GQA kv=16), MoE 64 experts top-6 + 2 shared,
expert FFN 1408, first layer dense (d_ff 11264), vocab 163840.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=11264,
    vocab_size=163840,
    attn_kind="gqa",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=5e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, n_experts=8, top_k=2, n_shared_experts=1,
        moe_d_ff=32, first_dense_layers=1, n_micro=1, q_chunk=32, kv_chunk=32,
        moe_impl="local", capacity_factor=8.0,
    )
