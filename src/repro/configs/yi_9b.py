"""Yi-9B (dense llama-arch GQA) — arXiv:2403.04652 (hf tier).

48L d_model=4096, 32 heads (GQA kv=4), d_ff=11008 (swiglu), vocab 64000.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652; hf:01-ai/Yi-9B",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, n_micro=1, q_chunk=32, kv_chunk=32,
    )
