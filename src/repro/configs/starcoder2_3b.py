"""StarCoder2-3B (dense, GQA, RoPE) — arXiv:2402.19173 (hf tier).

30L d_model=3072, 24 heads (GQA kv=2), d_ff=12288 (gelu), vocab 49152.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-3b",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    rope_theta=1e5,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, n_micro=1, q_chunk=32, kv_chunk=32,
    )
