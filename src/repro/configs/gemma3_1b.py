"""Gemma3-1B (dense, 5:1 local:global sliding window) — hf:google/gemma-3-1b-pt.

26L d_model=1152, 4 heads (GQA kv=1, head_dim 256), d_ff=6912 (geglu),
vocab 262144; sliding window 512 with every 6th layer global; local rope
theta 10k, global 1M; tied embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    act="geglu",
    tie_embeddings=True,
    window=512,
    global_every=6,
    rope_theta=1e4,
    rope_theta_global=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, d_head=32,
        d_ff=128, vocab_size=256, window=16, n_micro=1, q_chunk=32, kv_chunk=32,
    )
