"""SeamlessM4T-large-v2 backbone (enc-dec) — arXiv:2308.11596 (hf tier).

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=8192,
vocab 256206.  The speech/text modality frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
    n_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    norm="layernorm",
    frontend="frames",
    dec_ratio=4,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256, n_micro=1,
        q_chunk=32, kv_chunk=32,
    )
