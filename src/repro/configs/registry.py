"""Architecture registry + per-(arch, shape) input specs.

``input_specs(cfg, shape, model)`` returns jax.ShapeDtypeStruct stand-ins
for every input of the step function the shape's kind lowers:

  train    -> train_step(params, opt_state, batch{tokens, labels, ...})
  prefill  -> prefill(params, batch{tokens, ...})
  decode   -> decode_step(params, cache, token, cur_len)

Nothing here allocates device memory — caches/params come from
``jax.eval_shape``.  The BCPNN configs (the paper's own model) live here too
so --arch treats them uniformly.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_ARCH_MODULES: Dict[str, str] = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "yi-9b": "repro.configs.yi_9b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "internvl2-1b": "repro.configs.internvl2_1b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.smoke()


def all_cells():
    """Every assigned (arch, shape) cell with applicability flags."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape.name, ok, why


# ------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStructs for the data batch of a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        sd = s // cfg.dec_ratio
        specs = {
            "enc_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((b, sd), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = _sds((b, sd), jnp.int32)
        return specs
    if cfg.family == "vlm":
        p = min(cfg.n_patches, s // 4)
        st = s - p
        specs = {
            "embeds": _sds((b, p, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((b, st), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = _sds((b, st), jnp.int32)
        return specs
    specs = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((b, s), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model) -> Dict:
    """ShapeDtypeStructs for decode_step(cache, token, cur_len)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda: model.init_cache(b, s, max(s // cfg.dec_ratio, 1024))
        )
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "cache": cache,
        "token": _sds((b, 1), jnp.int32),
        "cur_len": _sds((), jnp.int32),
    }
