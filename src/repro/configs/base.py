"""Model/shape configuration schema + the assigned input-shape grid.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published numbers, source cited) and ``smoke()`` (a
reduced same-family config for CPU tests).  ``repro.configs.registry``
resolves ``--arch`` names.

The input-shape grid (assigned, LM-family):
  train_4k     seq 4096,   global_batch 256  -> train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill_step
  decode_32k   seq 32768,  global_batch 128  -> decode_step (1 new token)
  long_500k    seq 524288, global_batch 1    -> decode_step; SSM/hybrid/
               sliding-window archs only (sub-quadratic requirement)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    source: str  # citation for the numbers

    # trunk
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    # attention
    attn_kind: str = "gqa"  # gqa | mla | none
    rope_theta: float = 1e4
    window: Optional[int] = None  # sliding-window size (local layers)
    global_every: int = 0  # gemma3: every Nth layer is global (5:1 -> 6)
    rope_theta_global: Optional[float] = None  # gemma3 global layers
    q_chunk: int = 512
    kv_chunk: int = 1024

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "psum"  # local | psum | a2a
    aux_loss_coef: float = 0.001

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (zamba2): shared attention block every N mamba layers
    attn_every: int = 0
    # enc-dec (seamless)
    n_dec_layers: int = 0
    dec_ratio: int = 4  # decoder seq = seq // dec_ratio for train shapes
    # modality frontend stub (vlm/audio): inputs arrive as embeddings
    frontend: Optional[str] = None  # patch | frames
    n_patches: int = 0

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    n_micro: int = 8  # grad-accumulation microbatches for train_4k
    # Perf lever: cast f32 params to bf16 ONCE per train step (outside the
    # microbatch scan) so FSDP weight all-gathers move bf16 and are hoisted
    # loop-invariant — vs per-use casts after f32 gathers (baseline).
    cast_params_once: bool = False
    # Perf lever: zero-pad attention q-heads to this count at init so the
    # QKV/O projections AND the attention einsums shard over the model axis
    # when n_heads doesn't divide it.  Semantics-preserving: padded wq/wo
    # slices are zero, their gradients are identically zero.
    pad_heads_to: Optional[int] = None
    # Perf lever: vocab-sharded cross entropy (where/iota label pick instead
    # of take_along_axis, which GSPMD can only lower by replicating the
    # vocab-sharded logits).
    sharded_xent: bool = False
    # Perf lever: constrain gradients to the parameter shardings before the
    # optimizer so GSPMD emits reduce-scatter for the data-axis grad
    # reduction instead of all-reduce(+slice) — the FSDP grad flow.
    constrain_grads: bool = False

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell."""
        return self.family in ("ssm", "hybrid") or (
            self.window is not None and self.global_every > 0
        ) or (self.window is not None and self.global_every == 0)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings and self.family != "encdec":
            n += v * d  # unembed? (we tie by default when flag set)
        per_attn = 0
        if self.attn_kind == "gqa":
            per_attn = d * self.n_heads * self.d_head * 2 + \
                d * self.n_kv_heads * self.d_head * 2
        elif self.attn_kind == "mla":
            ql = self.q_lora_rank
            per_attn = (
                (d * ql + ql * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
                if ql
                else d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            )
            per_attn += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_attn += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim
            )
            per_attn += self.n_heads * self.v_head_dim * d
        per_mlp = (
            3 * d * self.d_ff if self.act in ("swiglu", "geglu") else 2 * d * self.d_ff
        )
        per_moe = 0
        if self.n_experts:
            per_moe = d * self.n_experts + 3 * self.n_experts * d * self.moe_d_ff
            per_moe += 3 * d * self.moe_d_ff * self.n_shared_experts
        per_ssm = 0
        if self.ssm_state:
            d_in = self.ssm_expand * d
            h = d_in // self.ssm_head_dim
            gn = self.ssm_groups * self.ssm_state
            per_ssm = d * (2 * d_in + 2 * gn + h) + d_in * d + \
                self.ssm_conv * (d_in + 2 * gn)

        if self.family == "dense" or self.family == "vlm":
            n += self.n_layers * (per_attn + per_mlp)
        elif self.family == "moe":
            n += self.first_dense_layers * (per_attn + per_mlp)
            n += (self.n_layers - self.first_dense_layers) * (per_attn + per_moe)
        elif self.family == "ssm":
            n += self.n_layers * per_ssm
        elif self.family == "hybrid":
            n += self.n_layers * per_ssm
            n += per_attn + per_mlp  # one shared transformer block
        elif self.family == "encdec":
            n += self.n_layers * (per_attn + per_mlp)
            n += self.n_dec_layers * (2 * per_attn + per_mlp)
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k + shared experts."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        per_attn_mlp = self.param_count() - (
            (self.n_layers - self.first_dense_layers)
            * (d * self.n_experts + 3 * self.n_experts * d * self.moe_d_ff)
        )
        active_moe = (self.n_layers - self.first_dense_layers) * (
            3 * self.top_k * d * self.moe_d_ff + d * self.n_experts
        )
        return per_attn_mlp + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable?, reason-if-not) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip noted in DESIGN.md)"
        )
    return True, ""
