# Assigned architectures (exact published numbers) + shape grid + smoke
# variants + input ShapeDtypeStruct specs for the dry-run.
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.registry import (
    ARCH_NAMES,
    all_cells,
    batch_specs,
    decode_specs,
    get_config,
    get_smoke_config,
)

__all__ = [
    "SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable",
    "ARCH_NAMES", "all_cells", "batch_specs", "decode_specs",
    "get_config", "get_smoke_config",
]
