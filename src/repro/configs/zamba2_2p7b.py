"""Zamba2-2.7B (hybrid: Mamba2 + shared attention blocks) — arXiv:2411.15242.

54 Mamba2 layers d_model=2560 (ssm_state=64, d_inner 5120, 80 heads of 64)
with one *shared* transformer block (32 heads, d_ff 10240) applied every 6
mamba layers; vocab 32000.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    attn_every=6,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        attn_every=2, n_micro=1, q_chunk=32, kv_chunk=32,
    )
