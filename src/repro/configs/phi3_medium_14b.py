"""Phi3-medium-14B (dense, RoPE SwiGLU GQA) — arXiv:2404.14219 (unverified).

40L d_model=5120, 40 heads (GQA kv=10), d_ff=17920, vocab 100352.
Note: 40 heads / 10 kv heads are not divisible by the 16-way model axis —
the sharding rule engine replicates the head axis and shards d_ff/vocab
instead (see repro.sharding.rules).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_head=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, n_micro=1, q_chunk=32, kv_chunk=32,
    )
