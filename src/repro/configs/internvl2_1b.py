"""InternVL2-1B backbone (VLM: InternViT stub + InternLM2) — arXiv:2404.16821.

24L d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab 151655.  The InternViT
patch frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (n_patches=1024 for train/prefill shapes).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab_size=151655,
    frontend="patch",
    n_patches=1024,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, dtype="float32", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, n_patches=8, n_micro=1, q_chunk=32,
        kv_chunk=32,
    )
