"""Reduced-precision BCPNN datapath emulation.

The paper's FPGA varies *every* floating-point operator (add/sub/mul/div/log
— "not only multiply-accumulate as in NVIDIA Tensorcore or Google TPU").  We
emulate that datapath by rounding to the target format at every algebraic
stage boundary of Alg. 1:

    support   s   = round(x @ w + b)
    softmax   a_j = round(softmax_HCU(s))
    means     m_* = round(<a>)                (the GEMM output)
    EWMA      C_* = round((1-λ)C + λ m)
    weights   w   = round(log C_ij - log C_i - log C_j)
    bias      b   = round(k_B log C_j)

Rounding *between* stages rather than per-scalar-op is the standard software
emulation fidelity (each stage is one fused hardware pipeline on the FPGA);
EXPERIMENTS.md §Validation/precision shows it reproduces the paper's
accuracy cliff (BF14 chance / BF15 partial / BF16 ~ -4% / BF20+ clean).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.learning import EPS, MarginalState
from repro.core.units import UnitLayout
from repro.precision.formats import BFFormat, get_format, round_to


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Which format each datapath stage runs in (uniform by default)."""

    fmt: BFFormat
    use_kernel: bool = True

    @classmethod
    def named(cls, name: str, use_kernel: bool = True) -> "PrecisionPolicy":
        return cls(fmt=get_format(name), use_kernel=use_kernel)

    def q(self, x: jnp.ndarray) -> jnp.ndarray:
        return round_to(x, self.fmt, use_kernel=self.use_kernel)


def quantized_forward(
    ai: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    layout: UnitLayout,
    policy: PrecisionPolicy,
    mask: Optional[jnp.ndarray] = None,
    gain: float = 1.0,
) -> jnp.ndarray:
    weff = policy.q(w * mask) if mask is not None else policy.q(w)
    s = policy.q(policy.q(ai) @ weff + policy.q(b))
    if gain != 1.0:
        s = policy.q(s * gain)
    blocked = layout.blocked(s)
    out = jax.nn.softmax(blocked, axis=-1)
    return policy.q(layout.flat(out))


def quantized_learning_cycle(
    state: MarginalState,
    ai: jnp.ndarray,
    aj: jnp.ndarray,
    lam: float,
    policy: PrecisionPolicy,
    k_b: float = 1.0,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[MarginalState, jnp.ndarray, jnp.ndarray]:
    b_sz = ai.shape[0]
    ai_q = policy.q(ai)
    aj_q = policy.q(aj)
    mi = policy.q(jnp.mean(ai_q, axis=0))
    mj = policy.q(jnp.mean(aj_q, axis=0))
    mij = policy.q(
        jnp.einsum("bi,bj->ij", ai_q, aj_q, preferred_element_type=jnp.float32)
        / b_sz
    )
    one_m = 1.0 - lam
    ci = policy.q(one_m * state.ci + lam * mi)
    cj = policy.q(one_m * state.cj + lam * mj)
    cij = policy.q(one_m * state.cij + lam * mij)
    new_state = MarginalState(ci=ci, cj=cj, cij=cij)
    w = policy.q(
        jnp.log(jnp.maximum(cij, EPS))
        - jnp.log(jnp.maximum(ci, EPS))[:, None]
        - jnp.log(jnp.maximum(cj, EPS))[None, :]
    )
    if mask is not None:
        w = w * mask
    bias = policy.q(k_b * jnp.log(jnp.maximum(cj, EPS)))
    return new_state, w, bias
