"""Reduced-precision BCPNN datapath emulation.

The paper's FPGA varies *every* floating-point operator (add/sub/mul/div/log
— "not only multiply-accumulate as in NVIDIA Tensorcore or Google TPU").  We
emulate that datapath by rounding to the target format at every algebraic
stage boundary of Alg. 1:

    support   s   = round(x @ w + b)
    softmax   a_j = round(softmax_HCU(s))
    means     m_* = round(<a>)                (the GEMM output)
    EWMA      C_* = round((1-λ)C + λ m)
    weights   w   = round(log C_ij - log C_i - log C_j)
    bias      b   = round(k_B log C_j)

Rounding *between* stages rather than per-scalar-op is the standard software
emulation fidelity (each stage is one fused hardware pipeline on the FPGA);
EXPERIMENTS.md §Validation/precision shows it reproduces the paper's
accuracy cliff (BF14 chance / BF15 partial / BF16 ~ -4% / BF20+ clean).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.learning import EPS, MarginalState
from repro.core.units import UnitLayout
from repro.precision.formats import BFFormat, get_format, round_to, state_spec


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Which format each datapath stage runs in (uniform by default).

    ``fmt`` is the *datapath* format (every algebraic stage rounds to it);
    ``state_format`` is the orthogonal *storage* tier: MarginalState traces
    (and decode caches) are kept rounded to that format between batches —
    bf16 stores them in actual ``jnp.bfloat16`` (half the HBM footprint,
    the olmax bf16-optimizer-EMA pattern), wider customs (bf20..) keep f32
    storage with the low mantissa bits zeroed.  Arithmetic always happens in
    f32; rounding is fused into the kernel epilogues on the kernel paths.
    A policy with an identity ``fmt`` and a ``state_format`` set gives the
    pure quantized-state tier (full-precision datapath, compressed state).
    """

    fmt: BFFormat
    use_kernel: bool = True
    state_format: Optional[BFFormat] = None

    @classmethod
    def named(
        cls,
        name: str,
        use_kernel: bool = True,
        state_format=None,
    ) -> "PrecisionPolicy":
        if isinstance(state_format, str):
            state_format = get_format(state_format)
        return cls(
            fmt=get_format(name), use_kernel=use_kernel,
            state_format=state_format,
        )

    def q(self, x: jnp.ndarray) -> jnp.ndarray:
        return round_to(x, self.fmt, use_kernel=self.use_kernel)

    @property
    def has_state_tier(self) -> bool:
        return self.state_format is not None and not self.state_format.is_identity

    def q_state(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round + cast one array into the state storage tier (identity when
        no state_format is set)."""
        mant, dtype = state_spec(self.state_format)
        if mant is None:
            return x
        y = round_to(
            x.astype(jnp.float32), self.state_format, use_kernel=self.use_kernel
        )
        return y.astype(dtype) if dtype is not None else y


def quantized_forward(
    ai: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    layout: UnitLayout,
    policy: PrecisionPolicy,
    mask: Optional[jnp.ndarray] = None,
    gain: float = 1.0,
) -> jnp.ndarray:
    weff = policy.q(w * mask) if mask is not None else policy.q(w)
    s = policy.q(policy.q(ai) @ weff + policy.q(b))
    if gain != 1.0:
        s = policy.q(s * gain)
    blocked = layout.blocked(s)
    out = jax.nn.softmax(blocked, axis=-1)
    return policy.q(layout.flat(out))


def quantized_learning_cycle(
    state: MarginalState,
    ai: jnp.ndarray,
    aj: jnp.ndarray,
    lam: float,
    policy: PrecisionPolicy,
    k_b: float = 1.0,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[MarginalState, jnp.ndarray, jnp.ndarray]:
    b_sz = ai.shape[0]
    ai_q = policy.q(ai)
    aj_q = policy.q(aj)
    mi = policy.q(jnp.mean(ai_q, axis=0))
    mj = policy.q(jnp.mean(aj_q, axis=0))
    mij = policy.q(
        jnp.einsum("bi,bj->ij", ai_q, aj_q, preferred_element_type=jnp.float32)
        / b_sz
    )
    one_m = 1.0 - lam
    # Traces may live in the state storage tier (bf16): upcast so the EWMA
    # arithmetic runs in f32 regardless of storage dtype.
    ci = policy.q(one_m * state.ci.astype(jnp.float32) + lam * mi)
    cj = policy.q(one_m * state.cj.astype(jnp.float32) + lam * mj)
    cij = policy.q(one_m * state.cij.astype(jnp.float32) + lam * mij)
    new_state = MarginalState(ci=ci, cj=cj, cij=cij)
    w = policy.q(
        jnp.log(jnp.maximum(cij, EPS))
        - jnp.log(jnp.maximum(ci, EPS))[:, None]
        - jnp.log(jnp.maximum(cj, EPS))[None, :]
    )
    if mask is not None:
        w = w * mask
    bias = policy.q(k_b * jnp.log(jnp.maximum(cj, EPS)))
    if policy.has_state_tier:
        new_state, w, bias = state_quantized_cycle(
            new_state, policy, k_b=k_b, mask=mask
        )
        w = policy.q(w)
        bias = policy.q(bias)
    return new_state, w, bias


def state_quantized_cycle(
    state: MarginalState,
    policy: PrecisionPolicy,
    k_b: float = 1.0,
    mask: Optional[jnp.ndarray] = None,
) -> Tuple[MarginalState, jnp.ndarray, jnp.ndarray]:
    """Round a freshly-updated MarginalState into the policy's state tier and
    re-derive w/bias from the *rounded* traces — the jnp mirror of the fused
    kernels' state-quantization epilogue.  Identity when no state tier."""
    if not policy.has_state_tier:
        w, bias = _weights_from(state, k_b, mask)
        return state, w, bias
    fmt = policy.state_format

    def rq(t):
        return round_to(t.astype(jnp.float32), fmt, use_kernel=policy.use_kernel)

    ci, cj, cij = rq(state.ci), rq(state.cj), rq(state.cij)
    w, bias = _weights_from(MarginalState(ci=ci, cj=cj, cij=cij), k_b, mask)
    _, dtype = state_spec(fmt)
    if dtype is not None:
        ci, cj, cij = ci.astype(dtype), cj.astype(dtype), cij.astype(dtype)
    return MarginalState(ci=ci, cj=cj, cij=cij), w, bias


def _weights_from(
    state: MarginalState, k_b: float, mask: Optional[jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ci = state.ci.astype(jnp.float32)
    cj = state.cj.astype(jnp.float32)
    cij = state.cij.astype(jnp.float32)
    w = (
        jnp.log(jnp.maximum(cij, EPS))
        - jnp.log(jnp.maximum(ci, EPS))[:, None]
        - jnp.log(jnp.maximum(cj, EPS))[None, :]
    )
    if mask is not None:
        w = w * mask
    bias = k_b * jnp.log(jnp.maximum(cj, EPS))
    return w, bias


def quantize_marginals(state: MarginalState, policy) -> MarginalState:
    """Cast a MarginalState into the policy's state storage tier (round +
    dtype cast) — used at compile time so jitted epoch scan carries start in
    the storage dtype and stay type-stable across batches."""
    if policy is None or not getattr(policy, "has_state_tier", False):
        return state
    return MarginalState(
        ci=policy.q_state(state.ci),
        cj=policy.q_state(state.cj),
        cij=policy.q_state(state.cij),
    )
