# Variable-precision (BF14..BF28) datapath emulation — the TPU-native
# realization of the paper's FPGA FloPoCo study (Sec. 4.2 / Fig. 3).
from repro.precision.formats import BFFormat, FORMATS, get_format, round_to
from repro.precision.policy import (
    PrecisionPolicy,
    quantized_forward,
    quantized_learning_cycle,
)

__all__ = [
    "BFFormat", "FORMATS", "get_format", "round_to",
    "PrecisionPolicy", "quantized_forward", "quantized_learning_cycle",
]
