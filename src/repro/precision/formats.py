"""Variable-precision bfloat formats (the paper's FPGA FloPoCo study).

The paper synthesizes IEEE-754-derived FPUs with an 8-bit exponent and a
reduced mantissa: BF14..BF28 where the total width n gives mantissa n-9
(sign + 8-bit exponent + mantissa).  BF16 (7-bit mantissa) is exactly the
Google-TPU bfloat16; BF14/BF15 are below it; BF20/24/28 above.  Paper
finding (Fig. 3): BF14 -> chance accuracy, BF15 -> ~67%, BF16 -> ~-4%,
BF20+ -> indistinguishable from f32.  We reproduce that sweep with RNE
mantissa-truncation emulation (see repro.kernels.bf_round).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BFFormat:
    name: str
    total_bits: int

    @property
    def mantissa_bits(self) -> int:
        # sign(1) + exponent(8) + mantissa
        return self.total_bits - 9

    @property
    def is_identity(self) -> bool:
        return self.mantissa_bits >= 23


FORMATS: Dict[str, BFFormat] = {
    f.name: f
    for f in [
        BFFormat("bf14", 14),
        BFFormat("bf15", 15),
        BFFormat("bf16", 16),
        BFFormat("bf20", 20),
        BFFormat("bf24", 24),
        BFFormat("bf28", 28),
        BFFormat("fp32", 32),
    ]
}


def get_format(name: str) -> BFFormat:
    try:
        return FORMATS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown format {name!r}; have {sorted(FORMATS)}"
        ) from None


def state_spec(fmt):
    """(mantissa_bits, storage_dtype) for keeping persistent state in `fmt`.

    The quantized-state tier (``PrecisionPolicy.state_format``) rounds the
    MarginalState traces / decode caches to ``mantissa_bits`` (RNE, fused
    into the kernel epilogues) and stores them in ``storage_dtype``:
    ``jnp.bfloat16`` when the rounded values are exactly representable there
    (mantissa <= 7, i.e. bf14/bf15/bf16 — halves the state's HBM footprint),
    otherwise ``None`` meaning f32 storage with the low mantissa bits zeroed
    (bf20/bf24/bf28 emulation).  Identity formats return ``(None, None)``.
    """
    if fmt is None or fmt.is_identity:
        return None, None
    mant = fmt.mantissa_bits
    return mant, (jnp.bfloat16 if mant <= 7 else None)


def round_to(x: jnp.ndarray, fmt: BFFormat, use_kernel: bool = True) -> jnp.ndarray:
    """Round f32 array to the format's mantissa width (RNE)."""
    if fmt.is_identity:
        return x.astype(jnp.float32)
    if use_kernel:
        from repro.kernels import ops

        return ops.bf_round(x, fmt.mantissa_bits)
    from repro.kernels import ref

    return ref.bf_round(x, fmt.mantissa_bits)
