# Hot-path guard subsystem: the static side (jaxlint, pure stdlib — safe to
# import without jax, which is how the CI lint job runs it) is re-exported
# eagerly; the runtime side (strict-mode verification) imports jax, so it
# loads lazily via __getattr__ to keep `import repro.analysis` jax-free.
from repro.analysis.lint import (
    DEFAULT_HOT_MODULES,
    RULES,
    Finding,
    lint_paths,
    lint_source,
)

_STRICT_EXPORTS = (
    "StrictViolation",
    "HostTransferError",
    "RecompileError",
    "NonFiniteError",
    "RecompileSentinel",
    "dispatch_guard",
    "finite_checker",
)


def __getattr__(name):
    if name in _STRICT_EXPORTS:
        from repro.analysis import strict

        return getattr(strict, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "DEFAULT_HOT_MODULES",
    "RULES",
    "Finding",
    "lint_paths",
    "lint_source",
    *_STRICT_EXPORTS,
]
