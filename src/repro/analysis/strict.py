"""Strict runtime verification: the dynamic side of the hot-path guard.

jaxlint (:mod:`repro.analysis.lint`) proves statically that no host sync sits
on a hot path; this module proves the *dynamic* properties the linter cannot
see: that dispatching a compiled epoch or fused decode step performs no
implicit host transfer, that no jitted callable silently retraces across
repeated ``fit`` / ``partial_fit`` / ``submit`` calls, and that the BCPNN
trace/weight updates stay finite.  ``ExecutionConfig(strict=True)`` /
``ServiceConfig(strict=True)`` turn all three on; the guards live entirely at
entry/exit of the already-batched dispatch calls, so the steady-state cost is
a context-manager enter per *epoch* (not per batch) and one cache-size
integer read per jitted callable per public call.

Three failure classes, three exceptions (all :class:`StrictViolation`):

* :class:`HostTransferError` — an *implicit* transfer happened inside a
  guarded dispatch (``jax.transfer_guard("disallow")``).  Explicit staging
  (``jnp.asarray`` / ``device_put``) is allowed; a numpy array silently
  falling into a jitted call is not.
* :class:`RecompileError` — a watched jitted callable's ``_cache_size()``
  grew after its baseline was taken: something fed it a new shape/dtype or
  a new static value.  New callables (a new layer, a new prefill bucket)
  get their own baseline; only *growth on the same callable* raises.
* :class:`NonFiniteError` — a ``checkify``-verified NaN/Inf in a state
  pytree (the EWMA traces and log-ratio weights are the usual victims of a
  too-aggressive learning rate or a zero marginal).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify


class StrictViolation(RuntimeError):
    """Base class for every strict-mode failure."""


class HostTransferError(StrictViolation):
    """Implicit host transfer inside a guarded dispatch."""


class RecompileError(StrictViolation):
    """A watched jitted callable re-traced after its baseline."""


class NonFiniteError(StrictViolation):
    """NaN/Inf detected in a guarded state pytree."""


# --------------------------------------------------------------- transfers
@contextlib.contextmanager
def dispatch_guard(enabled: bool = True) -> Iterator[None]:
    """``jax.transfer_guard("disallow")`` scoped to one dispatch, with the
    raw XlaRuntimeError translated into :class:`HostTransferError`.

    Wrap exactly the compiled-callable dispatch (the epoch scan, the fused
    decode step, the serving head) — inputs must already be staged with an
    *explicit* ``jnp.asarray`` / ``device_put`` (which the guard permits);
    telemetry readbacks belong outside the ``with``.  ``enabled=False`` is a
    no-op so call sites need no branching.
    """
    if not enabled:
        yield
        return
    try:
        with jax.transfer_guard("disallow"):
            yield
    except Exception as e:  # noqa: BLE001 — inspect, translate, or re-raise
        msg = str(e)
        if "transfer" in msg and ("isallow" in msg or "guard" in msg):
            raise HostTransferError(
                f"implicit host transfer inside a guarded dispatch: {msg} — "
                "stage inputs with an explicit jnp.asarray/device_put before "
                "the compiled call, or waive the site in jaxlint and keep it "
                "outside the guard"
            ) from e
        raise


# -------------------------------------------------------------- recompiles
class RecompileSentinel:
    """Tracks ``_cache_size()`` of watched jitted callables and raises on
    unexpected growth.

    ``watch(name, fn)`` is idempotent and cheap — call it with the *current*
    callable every time (registries grow: new layers, new prefill buckets,
    replaced epoch closures).  A replaced function object re-baselines; the
    same object growing its trace cache past the baseline raises
    :class:`RecompileError` at the next ``check()``.  Baselines are taken at
    the first ``check()`` that sees a non-empty cache, so warm-up traces
    never count as violations.
    """

    def __init__(self) -> None:
        # name -> (id(fn), fn, baseline cache size or None until warm)
        self._watched: Dict[str, Tuple[int, Any, Optional[int]]] = {}
        # Observability hook: called with the adopted sizes() after every
        # intentional rebaseline.  Stays None unless a tracing-enabled plan
        # binds it (this module must not import the trace module).
        self.on_rebaseline: Optional[Callable[[Dict[str, int]], None]] = None

    def watch(self, name: str, fn: Any) -> None:
        if fn is None or not hasattr(fn, "_cache_size"):
            return
        prev = self._watched.get(name)
        if prev is not None and prev[0] == id(fn):
            return
        self._watched[name] = (id(fn), fn, None)

    def watch_all(self, fns: Dict[str, Any], prefix: str = "") -> None:
        for name, fn in fns.items():
            self.watch(f"{prefix}{name}", fn)

    def sizes(self) -> Dict[str, int]:
        """Current trace-cache sizes of every watched callable."""
        return {
            name: fn._cache_size()
            for name, (_, fn, _b) in self._watched.items()
        }

    def check(self, where: str = "") -> None:
        """Baseline unbaselined warm callables; raise on growth."""
        for name, (fid, fn, baseline) in list(self._watched.items()):
            size = fn._cache_size()
            if baseline is None:
                if size >= 1:
                    self._watched[name] = (fid, fn, size)
                continue
            if size > baseline:
                ctx = f" during {where}" if where else ""
                raise RecompileError(
                    f"jitted callable {name!r} re-traced{ctx}: trace cache "
                    f"grew {baseline} -> {size}.  A new input shape/dtype or "
                    "static value reached a hot-path callable that is "
                    "supposed to compile exactly once."
                )

    def rebaseline(self) -> None:
        """Adopt current sizes as the new baselines (after an *intentional*
        shape change, e.g. reconfiguring a service)."""
        for name, (fid, fn, _b) in list(self._watched.items()):
            size = fn._cache_size()
            self._watched[name] = (fid, fn, size if size >= 1 else None)
        if self.on_rebaseline is not None:
            self.on_rebaseline(self.sizes())


# ------------------------------------------------------------ finite guard
def finite_checker() -> Callable:
    """A reusable finite-value guard over state pytrees.

    Returns ``check(tree, where="...")`` which verifies every inexact leaf
    of ``tree`` is finite via one jitted :mod:`checkify` call and raises
    :class:`NonFiniteError` naming the offending leaf's pytree path.  The
    checked function is cached per (paths, shapes, dtypes) structure, so
    per-epoch calls on a stable state cost one dispatch plus one scalar
    error-flag readback.
    """
    cache: Dict[Any, Callable] = {}

    def _build(paths: Tuple[str, ...]) -> Callable:
        def body(leaves):
            for path, leaf in zip(paths, leaves):
                checkify.check(
                    jnp.all(jnp.isfinite(leaf)),
                    f"non-finite values in {path}",
                )

        return jax.jit(checkify.checkify(body))

    def check(tree: Any, where: str = "state") -> None:
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        items = [
            (jax.tree_util.keystr(path), leaf)
            for path, leaf in flat
            if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.inexact)
        ]
        if not items:
            return
        paths = tuple(p for p, _ in items)
        leaves = [leaf for _, leaf in items]
        key = (paths, tuple((leaf.shape, str(leaf.dtype)) for leaf in leaves))
        fn = cache.get(key)
        if fn is None:
            fn = _build(paths)
            cache[key] = fn
        err, _ = fn(leaves)
        try:
            err.throw()
        except checkify.JaxRuntimeError as e:
            raise NonFiniteError(f"{where}: {e}") from e

    return check


__all__ = [
    "StrictViolation",
    "HostTransferError",
    "RecompileError",
    "NonFiniteError",
    "dispatch_guard",
    "RecompileSentinel",
    "finite_checker",
]
