"""jaxlint: repo-specific JAX static analysis (the hot-path guard, static side).

StreamBrain's value is that the BCPNN hot loops run as fast as the hardware
allows — and the failure modes that silently regress that are not syntax
errors: a host sync inside a scan body, a buffer read after donation, a
Python mutable reaching a trace as a baked-in constant, an unlocked write to
state the async engine's executor thread shares.  This module is a pure-AST
lint pass (stdlib only — no jax import, so the CI lint job runs it without
installing jax) with four repo-specific rules:

JL001  host-sync / host-transfer call in traced code or a hot module.
       ``np.asarray``, ``np.array``, ``jax.device_get``, ``.item()``,
       ``.tolist()``, ``block_until_ready`` and jax-valued ``float()`` /
       ``int()`` casts are flagged (a) inside any function passed to
       ``jax.jit`` / ``lax.scan`` / ``vmap`` / ``shard_map`` / ``grad`` or
       decorated with them — where they either break tracing or force a
       device sync per call — and (b) ANYWHERE in the designated hot-path
       modules (:data:`DEFAULT_HOT_MODULES`), so every host transfer in the
       serving/training dispatch loops is either removed or carries an
       explicit waiver documenting why it is load-bearing.
JL002  donation-after-use: a buffer passed at a ``donate_argnums`` position
       of a jitted callable is read again after the call — donation
       invalidates the buffer, so the read returns garbage (or errors) on
       accelerators while silently "working" on CPU.
JL003  recompile hazards: a ``jax.jit`` (or other trace wrapper) constructed
       inside a loop (a fresh trace cache per iteration), an unhashable
       literal (list/dict/set) passed at a ``static_argnums``/``argnames``
       position, or a traced function closing over an enclosing scope's
       mutable literal (the trace bakes it in as a constant; later mutation
       is silently ignored).
JL004  unlocked shared-state mutation: in a class that owns a
       ``threading.Lock`` / ``RLock`` / ``Condition``, any write to a
       ``self.*`` attribute outside ``__init__`` that is not lexically under
       ``with self.<lock>:`` — the discipline ``repro.runtime.metrics``
       follows, enforced everywhere the AsyncEngine's executor thread (or
       the Router's scheduler thread) can race a caller thread.  A class
       whose lock arrives indirectly (constructor parameter, shared bundle
       lock) registers it via a class attribute so coverage never silently
       lapses::

           class Counter:
               _JAXLINT_LOCKS = ("_lock",)   # JL004 registration
               def __init__(self, lock=None):
                   self._lock = lock if lock is not None else threading.Lock()

       Methods named ``*_locked`` are exempt: the suffix is a naming
       contract (the CPython convention) that the CALLER holds the lock —
       the ``with`` block lives one frame up where a lexical check cannot
       see it.

Waivers
-------
The ONLY suppression mechanism is an inline waiver comment with a reason::

    nxt = np.asarray(nxt)  # jaxlint: allow[JL001] reason=tokens steer EOS host-side

A waiver on its own line covers the next code line; several rules may be
listed (``allow[JL001,JL004]``).  A waiver without a reason, and a waiver
that matches no finding, are themselves findings (JL000) — waivers never rot.

CLI: ``tools/jaxlint [paths...]`` (or ``python -m repro.analysis.lint``);
exits non-zero when findings remain.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import io
import os
import re
import sys
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = {
    "JL000": "malformed or unused waiver",
    "JL001": "host sync / transfer on a hot path",
    "JL002": "buffer used after donation",
    "JL003": "recompile hazard",
    "JL004": "unlocked shared-state mutation",
}

# Modules whose WHOLE body is a hot path: every host transfer here must be
# deliberate, so JL001 applies module-wide (not just inside traced code).
DEFAULT_HOT_MODULES: Tuple[str, ...] = (
    "repro/runtime/service.py",
    "repro/runtime/engine.py",
    "repro/runtime/router.py",
    "repro/runtime/continual.py",
    "repro/runtime/trace.py",
    "repro/runtime/export.py",
    "repro/runtime/plans.py",
    "repro/runtime/epoch_engine.py",
    "repro/runtime/program.py",
    "repro/core/compiled.py",
    "repro/kernels/ops.py",
    "repro/kernels/bcpnn_phase.py",
)

# Dotted-call suffixes that enter a trace; their first positional argument is
# traced Python code.
_TRACE_WRAPPERS = {
    "jax.jit", "jit",
    "jax.vmap", "vmap",
    "jax.pmap", "pmap",
    "jax.lax.scan", "lax.scan",
    "jax.grad", "grad",
    "jax.value_and_grad", "value_and_grad",
    "jax.checkpoint", "jax.remat",
    "shard_map", "jax.experimental.shard_map.shard_map",
    "checkify.checkify",
}

# Host-sync / host-transfer calls (JL001).
_SYNC_DOTTED = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get", "device_get",
    "jax.block_until_ready",
}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_CAST_BUILTINS = {"float", "int", "bool"}

_LOCK_FACTORIES = {
    "threading.Lock", "threading.RLock", "threading.Condition",
    "Lock", "RLock", "Condition",
}

_MUTABLE_LITERALS = (
    ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp,
)

_WAIVER_RE = re.compile(
    r"#\s*jaxlint:\s*allow\[([A-Za-z0-9,\s]+)\]\s*(?:reason=(.+))?$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class _Waiver:
    line: int          # comment's own line
    covers: Set[int]   # code lines the waiver applies to
    rules: Set[str]
    reason: str
    used: bool = False


# --------------------------------------------------------------------------
# Small AST helpers.
# --------------------------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _matches(dotted: Optional[str], suffixes: Set[str]) -> bool:
    if dotted is None:
        return False
    return dotted in suffixes or any(
        dotted.endswith("." + s) for s in suffixes
    )


def _trace_call(call: ast.Call) -> Optional[ast.Call]:
    """The trace-wrapper call underlying ``call`` — handles the direct form
    and ``functools.partial(jax.jit, ...)``."""
    dotted = _dotted(call.func)
    if _matches(dotted, _TRACE_WRAPPERS):
        return call
    if _matches(dotted, {"functools.partial", "partial"}) and call.args:
        inner = _dotted(call.args[0])
        if _matches(inner, _TRACE_WRAPPERS):
            return call
    return None


def _mentions_jax(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in ("jax", "jnp", "lax")
        for n in ast.walk(node)
    )


def _static_looking(node: ast.AST) -> bool:
    """Casts of shapes/lengths/constants are static under trace — skip."""
    if isinstance(node, ast.Constant):
        return True
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim", "size"):
            return True
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) and n.func.id == "len":
            return True
    return False


def _int_or_ints(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return out
    return []


class _Parents(ast.NodeVisitor):
    """parent map + per-node enclosing statement."""

    def __init__(self, tree: ast.AST):
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        while node in self.parent:
            node = self.parent[node]
            yield node

    def statement(self, node: ast.AST) -> ast.AST:
        last = node
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda, ast.Module, ast.ClassDef)):
                return last
            last = anc
        return last


# --------------------------------------------------------------------------
# The per-file linter.
# --------------------------------------------------------------------------
class _FileLint:
    def __init__(self, src: str, path: str, hot: Sequence[str]):
        self.src = src
        self.path = path
        self.findings: List[Finding] = []
        self.tree = ast.parse(src, filename=path)
        self.parents = _Parents(self.tree)
        norm = path.replace(os.sep, "/")
        self.is_hot = any(norm.endswith(h) for h in hot)
        self.waivers = self._parse_waivers(src)

    # ------------------------------------------------------------- waivers
    def _parse_waivers(self, src: str) -> List[_Waiver]:
        waivers: List[_Waiver] = []
        code_tokens_on: Set[int] = set()
        comments: List[Tuple[int, str]] = []
        try:
            for tok in tokenize.generate_tokens(io.StringIO(src).readline):
                if tok.type == tokenize.COMMENT:
                    comments.append((tok.start[0], tok.string))
                elif tok.type not in (
                    tokenize.NL, tokenize.NEWLINE, tokenize.INDENT,
                    tokenize.DEDENT, tokenize.ENCODING, tokenize.ENDMARKER,
                ):
                    for ln in range(tok.start[0], tok.end[0] + 1):
                        code_tokens_on.add(ln)
        except tokenize.TokenError:
            return waivers
        for line, text in comments:
            m = _WAIVER_RE.search(text)
            if m is None:
                if re.search(r"jaxlint\s*:", text):
                    self._emit("JL000", line, 0,
                               "unparseable jaxlint comment (want "
                               "'# jaxlint: allow[JLxxx] reason=...')")
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(2) or "").strip()
            bad = rules - set(RULES)
            if bad:
                self._emit("JL000", line, 0,
                           f"waiver names unknown rule(s) {sorted(bad)}")
                continue
            if not reason:
                self._emit("JL000", line, 0,
                           "waiver without a reason= — document why the "
                           "transfer/mutation is load-bearing")
                continue
            covers = {line}
            if line not in code_tokens_on:  # comment-only line: covers next
                covers.add(line + 1)
            waivers.append(_Waiver(line, covers, rules, reason))
        return waivers

    def _emit(self, rule: str, line: int, col: int, message: str) -> None:
        self.findings.append(Finding(self.path, line, col, rule, message))

    # ---------------------------------------------------------------- run
    def run(self) -> List[Finding]:
        traced = self._traced_functions()
        self._check_sync_calls(traced)
        self._check_donation_and_static()
        self._check_jit_in_loop()
        self._check_closure_mutables(traced)
        self._check_lock_discipline()
        return self._apply_waivers()

    def _apply_waivers(self) -> List[Finding]:
        kept: List[Finding] = []
        for f in self.findings:
            if f.rule == "JL000":
                kept.append(f)
                continue
            waived = False
            for w in self.waivers:
                if f.line in w.covers and f.rule in w.rules:
                    w.used = True
                    waived = True
                    break
            if not waived:
                kept.append(f)
        for w in self.waivers:
            if not w.used:
                kept.append(Finding(
                    self.path, w.line, 0, "JL000",
                    f"waiver allow[{','.join(sorted(w.rules))}] matches no "
                    "finding — delete it",
                ))
        kept.sort(key=lambda f: (f.line, f.col, f.rule))
        return kept

    # ----------------------------------------------------- traced regions
    def _traced_functions(self) -> Set[ast.AST]:
        """Function nodes (def/lambda) whose bodies execute under a trace."""
        traced: Set[ast.AST] = set()

        def resolve_name(name: str, from_node: ast.AST) -> Optional[ast.AST]:
            # Nearest enclosing scope defining a function with this name.
            scopes = [self.tree] + [
                a for a in self.parents.ancestors(from_node)
                if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))
            ]
            for scope in scopes:
                for child in ast.walk(scope):
                    if (isinstance(child, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
                            and child.name == name):
                        return child
            return None

        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and _trace_call(node) is not None:
                args = node.args
                # partial(jax.jit, f, ...) puts the fn at index 1.
                dotted = _dotted(node.func)
                if _matches(dotted, {"functools.partial", "partial"}):
                    args = node.args[1:]
                if not args:
                    continue
                fn = args[0]
                if isinstance(fn, ast.Lambda):
                    traced.add(fn)
                elif isinstance(fn, ast.Name):
                    target = resolve_name(fn.id, node)
                    if target is not None:
                        traced.add(target)
                elif isinstance(fn, ast.Attribute):
                    target = resolve_name(fn.attr, node)
                    if target is not None:
                        traced.add(target)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = dec.func if isinstance(dec, ast.Call) else dec
                    if _matches(_dotted(d), _TRACE_WRAPPERS) or (
                        isinstance(dec, ast.Call)
                        and _trace_call(dec) is not None
                    ):
                        traced.add(node)
        return traced

    def _in_traced(self, node: ast.AST, traced: Set[ast.AST]) -> bool:
        if node in traced:
            return True
        return any(a in traced for a in self.parents.ancestors(node))

    # ------------------------------------------------------------- JL001
    def _check_sync_calls(self, traced: Set[ast.AST]) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            in_trace = self._in_traced(node, traced)
            if not in_trace and not self.is_hot:
                continue
            where = (
                "inside traced code (breaks tracing or syncs per call)"
                if in_trace else "on a hot-path module"
            )
            dotted = _dotted(node.func)
            if _matches(dotted, _SYNC_DOTTED):
                self._emit("JL001", node.lineno, node.col_offset,
                           f"host transfer `{dotted}` {where}")
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_METHODS
                    and not node.args):
                self._emit("JL001", node.lineno, node.col_offset,
                           f"host sync `.{node.func.attr}()` {where}")
                continue
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _CAST_BUILTINS
                    and len(node.args) == 1):
                arg = node.args[0]
                if _static_looking(arg):
                    continue
                # In a hot module (but outside traced code) only flag casts
                # of jax-valued expressions — host bookkeeping ints are fine.
                if in_trace or _mentions_jax(arg):
                    self._emit(
                        "JL001", node.lineno, node.col_offset,
                        f"`{node.func.id}()` of a device value {where}",
                    )

    # ------------------------------------------------- JL002/JL003 (calls)
    def _function_scopes(self) -> List[ast.AST]:
        return [self.tree] + [
            n for n in ast.walk(self.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]

    def _check_donation_and_static(self) -> None:
        for scope in self._function_scopes():
            donated: Dict[str, List[int]] = {}
            statics: Dict[str, Tuple[List[int], List[str]]] = {}
            body = scope.body if hasattr(scope, "body") else []
            # Pass 1: jitted-callable bindings in this scope.
            for stmt in body if isinstance(body, list) else []:
                if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                    continue
                target = stmt.targets[0]
                if not isinstance(target, ast.Name):
                    continue
                call = stmt.value
                if not isinstance(call, ast.Call) or _trace_call(call) is None:
                    continue
                for kw in call.keywords:
                    if kw.arg == "donate_argnums":
                        pos = _int_or_ints(kw.value)
                        if pos:
                            donated[target.id] = pos
                    elif kw.arg == "static_argnums":
                        pos = _int_or_ints(kw.value)
                        if pos:
                            statics.setdefault(target.id, ([], []))[0].extend(pos)
                    elif kw.arg == "static_argnames":
                        names = []
                        if isinstance(kw.value, ast.Constant):
                            names = [str(kw.value.value)]
                        elif isinstance(kw.value, (ast.Tuple, ast.List)):
                            names = [
                                str(e.value) for e in kw.value.elts
                                if isinstance(e, ast.Constant)
                            ]
                        if names:
                            statics.setdefault(target.id, ([], []))[1].extend(names)
            if not donated and not statics:
                continue
            # Pass 2: call sites within this scope (nested defs excluded from
            # the "after" analysis but included as uses).
            events = self._name_events(scope)
            for node in ast.walk(scope):
                if not isinstance(node, ast.Call):
                    continue
                fname = node.func.id if isinstance(node.func, ast.Name) else None
                if fname in statics:
                    pos, names = statics[fname]
                    for p in pos:
                        if p < len(node.args) and isinstance(
                            node.args[p], _MUTABLE_LITERALS
                        ):
                            self._emit(
                                "JL003", node.lineno, node.col_offset,
                                f"unhashable literal at static_argnums[{p}] "
                                f"of `{fname}` — every call re-traces (or "
                                "TypeErrors)",
                            )
                    for kw in node.keywords:
                        if kw.arg in names and isinstance(
                            kw.value, _MUTABLE_LITERALS
                        ):
                            self._emit(
                                "JL003", node.lineno, node.col_offset,
                                f"unhashable literal for static arg "
                                f"`{kw.arg}` of `{fname}`",
                            )
                if fname in donated:
                    stmt = self.parents.statement(node)
                    end = getattr(stmt, "end_lineno", node.lineno)
                    for p in donated[fname]:
                        if p >= len(node.args):
                            continue
                        arg = node.args[p]
                        if not isinstance(arg, ast.Name):
                            continue
                        # `state, xs = epoch(state, xs)` rebinds the donated
                        # name in the same statement — the post-call buffer
                        # replaces the dead one, so later reads are fine.
                        if isinstance(stmt, (ast.Assign, ast.AugAssign)) and any(
                            isinstance(t, ast.Name)
                            and t.id == arg.id
                            and isinstance(t.ctx, ast.Store)
                            for tgt in getattr(stmt, "targets", [stmt])
                            for t in ast.walk(tgt)
                        ):
                            continue
                        use = self._first_use_after(events, arg.id, end)
                        if use is not None:
                            self._emit(
                                "JL002", use, node.col_offset,
                                f"`{arg.id}` read after being donated to "
                                f"`{fname}` (line {node.lineno}) — donation "
                                "invalidates the buffer on accelerators",
                            )

    def _name_events(self, scope: ast.AST) -> List[Tuple[int, str, str]]:
        """(line, name, 'load'|'store') events in statement order."""
        events: List[Tuple[int, str, str]] = []
        for node in ast.walk(scope):
            if isinstance(node, ast.Name):
                kind = "store" if isinstance(
                    node.ctx, (ast.Store, ast.Del)
                ) else "load"
                events.append((node.lineno, node.id, kind))
        events.sort(key=lambda e: e[0])
        return events

    @staticmethod
    def _first_use_after(
        events: List[Tuple[int, str, str]], name: str, after_line: int
    ) -> Optional[int]:
        """First load of ``name`` strictly after ``after_line`` that is not
        preceded by a re-binding store."""
        for line, nm, kind in events:
            if nm != name or line <= after_line:
                continue
            return line if kind == "load" else None
        return None

    # ------------------------------------------------------------- JL003
    def _check_jit_in_loop(self) -> None:
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call) and _trace_call(node) is not None):
                continue
            for anc in self.parents.ancestors(node):
                if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                    break  # loops outside the defining function don't apply
                if isinstance(anc, (ast.For, ast.While)):
                    dotted = _dotted(node.func) or "trace wrapper"
                    self._emit(
                        "JL003", node.lineno, node.col_offset,
                        f"`{dotted}` constructed inside a loop — a fresh "
                        "trace cache every iteration (hoist it)",
                    )
                    break

    def _check_closure_mutables(self, traced: Set[ast.AST]) -> None:
        for fn in traced:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                continue
            enclosing = next(
                (a for a in self.parents.ancestors(fn)
                 if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))),
                None,
            )
            if enclosing is None:
                continue
            bound = self._bound_names(fn)
            free = {
                n.id for n in ast.walk(fn)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                and n.id not in bound
            }
            for stmt in ast.walk(enclosing):
                if not isinstance(stmt, ast.Assign):
                    continue
                if not isinstance(stmt.value, _MUTABLE_LITERALS):
                    continue
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id in free:
                        self._emit(
                            "JL003", fn.lineno, fn.col_offset,
                            f"traced function closes over mutable `{t.id}` "
                            f"(bound line {stmt.lineno}) — baked in as a "
                            "constant at trace time; later mutation is "
                            "silently ignored",
                        )

    @staticmethod
    def _bound_names(fn: ast.AST) -> Set[str]:
        bound: Set[str] = set()
        args = fn.args
        for a in (
            list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
        ):
            bound.add(a.arg)
        if args.vararg:
            bound.add(args.vararg.arg)
        if args.kwarg:
            bound.add(args.kwarg.arg)
        for n in ast.walk(fn):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                bound.add(n.id)
            elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                bound.add(n.name)
        return bound

    # ------------------------------------------------------------- JL004
    def _check_lock_discipline(self) -> None:
        classes = {
            n.name: n for n in ast.walk(self.tree)
            if isinstance(n, ast.ClassDef)
        }
        lock_attrs: Dict[str, Set[str]] = {}

        def own_locks(cls: ast.ClassDef) -> Set[str]:
            attrs: Set[str] = set()
            for node in ast.walk(cls):
                if not isinstance(node, ast.Assign):
                    continue
                # Explicit registration: `_JAXLINT_LOCKS = ("_lock", ...)` as
                # a class attribute — for locks that arrive indirectly (a
                # constructor parameter, a bundle-shared lock) where no
                # factory call is visible to the pattern below.
                if (len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == "_JAXLINT_LOCKS"
                        and isinstance(node.value, (ast.Tuple, ast.List))):
                    for e in node.value.elts:
                        if isinstance(e, ast.Constant) and isinstance(
                            e.value, str
                        ):
                            attrs.add(e.value)
                    continue
                if not (isinstance(node.value, ast.Call)
                        and _matches(_dotted(node.value.func), _LOCK_FACTORIES)):
                    continue
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        attrs.add(t.attr)
            return attrs

        def all_locks(name: str, seen: Set[str]) -> Set[str]:
            if name in lock_attrs:
                return lock_attrs[name]
            if name in seen or name not in classes:
                return set()
            seen.add(name)
            cls = classes[name]
            attrs = set(own_locks(cls))
            for base in cls.bases:
                if isinstance(base, ast.Name):
                    attrs |= all_locks(base.id, seen)
            lock_attrs[name] = attrs
            return attrs

        for name, cls in classes.items():
            locks = all_locks(name, set())
            if not locks:
                continue
            for method in cls.body:
                if not isinstance(method, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                    continue
                if method.name in ("__init__", "__new__"):
                    continue
                if method.name.endswith("_locked"):
                    # Naming contract: a `*_locked` method documents that
                    # its CALLER holds the lock (the CPython convention);
                    # the with-block lives one frame up where the linter
                    # cannot see it.
                    continue
                self._check_method_writes(method, locks)

    def _check_method_writes(self, method: ast.AST, locks: Set[str]) -> None:
        for node in ast.walk(method):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            for t in targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    continue
                if t.attr in locks:
                    continue
                if self._under_lock(node, locks):
                    continue
                self._emit(
                    "JL004", node.lineno, node.col_offset,
                    f"write to `self.{t.attr}` outside `with self."
                    f"{'/'.join(sorted(locks))}` in a lock-owning class — "
                    "the executor thread can race this",
                )

    def _under_lock(self, node: ast.AST, locks: Set[str]) -> bool:
        for anc in self.parents.ancestors(node):
            if isinstance(anc, ast.With):
                for item in anc.items:
                    e = item.context_expr
                    if (isinstance(e, ast.Attribute)
                            and isinstance(e.value, ast.Name)
                            and e.value.id == "self" and e.attr in locks):
                        return True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        return False


# --------------------------------------------------------------------------
# Public API + CLI.
# --------------------------------------------------------------------------
def lint_source(
    src: str, path: str = "<string>",
    hot: Sequence[str] = DEFAULT_HOT_MODULES,
) -> List[Finding]:
    """Lint one source string; ``path`` decides hot-module status."""
    try:
        return _FileLint(src, path, hot).run()
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "JL000",
                        f"syntax error: {e.msg}")]


def lint_paths(
    paths: Sequence[str], hot: Sequence[str] = DEFAULT_HOT_MODULES,
) -> List[Finding]:
    """Lint files and directory trees (``*.py``)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files.extend(
                    os.path.join(root, n) for n in names if n.endswith(".py")
                )
        else:
            files.append(p)
    findings: List[Finding] = []
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), f, hot))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="jaxlint", description="repo-specific JAX static analysis"
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--hot", action="append", default=None,
        help="extra hot-path module suffix (repeatable); defaults to the "
        "serving/training dispatch modules",
    )
    args = ap.parse_args(argv)
    hot = list(DEFAULT_HOT_MODULES) + (args.hot or [])
    findings = lint_paths(args.paths, hot=hot)
    for f in findings:
        print(f.render())
    if findings:
        print(f"jaxlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
