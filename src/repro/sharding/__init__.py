# PartitionSpec rule engine: logical axis names -> mesh axes with
# divisibility fallback (DP/FSDP/TP/EP/SP expressed declaratively).
from repro.sharding.rules import DEFAULT_RULES, L, ShardCtx, param_shardings, param_specs

__all__ = ["DEFAULT_RULES", "L", "ShardCtx", "param_shardings", "param_specs"]
