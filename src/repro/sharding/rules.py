"""Logical-axis sharding rules (MaxText-style) for the LM model zoo.

Every parameter/activation dimension carries a *logical* name; a rule table
maps logical names to mesh axes, with automatic divisibility fallback: if a
tensor dimension is not divisible by the mesh axis size the rule degrades to
replication for that dimension (this is how e.g. phi3's 40 heads coexist
with a 16-way model axis without padding — heads replicate, d_ff shards).

Mesh axes (launch/mesh.py):
  pod    (multi-pod only) — outermost data parallelism across pods
  data   — data parallelism + FSDP weight sharding
  model  — tensor/expert parallelism + sequence parallelism for caches

The default rule table:
  batch      -> (pod, data)     activations' batch dim
  seq        -> None            (model for SP when requested)
  embed      -> None            d_model on activations
  vocab      -> model           embedding rows / logits
  heads      -> model           attention q heads
  kv_heads   -> model           attention kv heads / kv cache heads
  qkv        -> None            per-head dim
  mlp        -> model           FFN hidden
  expert     -> model           MoE expert axis (EP)
  d_fsdp     -> data            weight d_model dim (ZeRO-3 style FSDP)
  cache_seq  -> model           KV-cache sequence axis (SP for decode)
  layer      -> None            scanned-layer leading axis
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "attn_seq": None,   # attention q seq (SP lever)
    "q_groups": None,   # padded head-group parallelism lever
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": None,
    "mlp": "model",
    "expert": "model",
    "d_fsdp": "data",
    "cache_seq": "model",
    "sp_seq": "model",
    "cache_batch": ("pod", "data"),
    "layer": None,
    "ssm_heads": "model",
    "ssm_state": None,
}


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + rule table + helpers; mesh=None degrades to no-ops (CPU smoke)."""

    mesh: Optional[Mesh] = None
    rules: Tuple[Tuple[str, Axis], ...] = tuple(DEFAULT_RULES.items())
    # Probe mode: fully unroll every lax.scan so compiled.cost_analysis()
    # counts all iterations (XLA costs a while body ONCE — see launch/roofline).
    unroll: bool = False

    @property
    def rule_map(self) -> Dict[str, Axis]:
        return dict(self.rules)

    def with_rules(self, **overrides: Axis) -> "ShardCtx":
        m = self.rule_map
        m.update(overrides)
        return ShardCtx(mesh=self.mesh, rules=tuple(m.items()), unroll=self.unroll)

    # -------------------------------------------------------------- mapping
    def _axis_size(self, axis: Axis) -> int:
        if axis is None or self.mesh is None:
            return 1
        if isinstance(axis, str):
            return self.mesh.shape[axis] if axis in self.mesh.axis_names else 1
        n = 1
        for a in axis:
            n *= self.mesh.shape[a] if a in self.mesh.axis_names else 1
        return n

    def _present(self, axis: Axis) -> Axis:
        """Drop mesh axes that don't exist on this mesh (pod on single-pod)."""
        if axis is None or self.mesh is None:
            return None
        if isinstance(axis, str):
            return axis if axis in self.mesh.axis_names else None
        kept = tuple(a for a in axis if a in self.mesh.axis_names)
        return kept if kept else None

    def spec(self, logical: Sequence[Optional[str]], shape=None) -> P:
        """PartitionSpec for a tensor with the given logical dim names.

        If `shape` is given, any dim not divisible by its mapped axis size
        falls back to replication (the production fallback for odd head
        counts etc.).
        """
        rm = self.rule_map
        out = []
        used = set()
        for i, name in enumerate(logical):
            ax = self._present(rm.get(name)) if name is not None else None
            if ax is not None and shape is not None:
                if shape[i] % self._axis_size(ax) != 0:
                    ax = None
            # A mesh axis may shard at most one tensor dim: first dim wins
            # (e.g. KV caches name both cache_seq and kv_heads -> model; the
            # seq dim takes it, heads replicate — override rules to flip).
            if ax is not None:
                axes = (ax,) if isinstance(ax, str) else tuple(ax)
                if any(a in used for a in axes):
                    ax = None
                else:
                    used.update(axes)
            out.append(ax)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]], shape=None):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def cs(self, x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
        """with_sharding_constraint if a mesh is present, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical, x.shape))
        )

    def batch_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]


class L:
    """Logical-axes annotation leaf (deliberately NOT a pytree container, so
    a tree of L(...) mirrors a params tree leaf-for-leaf under tree_map)."""

    __slots__ = ("names",)

    def __init__(self, *names: Optional[str]):
        self.names = names

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"L{self.names}"


def param_specs(ctx: ShardCtx, params, logical_tree):
    """PartitionSpec pytree for params given a mirroring tree of L leaves."""
    return jax.tree_util.tree_map(
        lambda p, lg: ctx.spec(lg.names, jnp.shape(p)), params, logical_tree
    )


def param_shardings(ctx: ShardCtx, params, logical_tree):
    """NamedSharding pytree (or None when meshless) for a params tree."""
    if ctx.mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda p, lg: NamedSharding(ctx.mesh, ctx.spec(lg.names, jnp.shape(p))),
        params,
        logical_tree,
    )
